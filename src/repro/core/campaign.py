"""Campaign: many explorations, one cross-batched simulation stream.

FARSI's experiments are never a single search — Fig. 9/10 average seeds,
Fig. 9b sweeps the awareness ladder, §6 sweeps budgets and workloads. A
``Campaign`` declares that whole grid up front, then runs it as a thin
client of the serve layer's continuous-batching engine
(`repro.serve.ContinuousBatchScheduler`): every run becomes a `Session`
admitted before the first tick, and each tick packs the pending candidate
batches of *all* live explorers on a workload into **one**
``backend.evaluate_candidates`` dispatch. Because every session joins up
front and per-row results are independent of batch composition, this is
exactly the historic lockstep sweep — same converged runs, same iteration
counts — while mid-flight-joining consumers (``repro.serve.DseService``)
share the identical engine. With `JaxBatchedBackend` that turns N
concurrent searches into single batched dispatches of N×neighbours
delta-encoded candidates — the batching the vectorized simulator was built
for — while `PythonBackend` campaigns still benefit from the shared
accounting. One backend is shared per distinct task graph (the encoding is
workload-specific); per-run ``n_sims`` stays with each explorer. Passing a
``store=`` (`repro.serve.DesignStore`) memoizes evaluations content-
addressed on ``hash(encoding, workload, budget)`` and surfaces
``cache_*`` counters in the aggregate.

Runs whose config opts into device chain blocks (``chain_r > 0``) ride the
same engine: their sessions yield :class:`~repro.core.device_explore.ChainRequest`
blocks that the scheduler prices as one fused device dispatch each, instead
of joining the shared candidate pack. ``run()`` flushes every backend before
reporting so no abandoned dispatch outlives the campaign.
"""
from __future__ import annotations

import dataclasses
import statistics
import time
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Union

from .backend import BackendStats, SimulatorBackend
from .budgets import Budget
from .codesign import aggregate_ledgers
from .database import HardwareDatabase
from .design import Design
from .explorer import ExplorationResult, Explorer, ExplorerConfig
from .tdg import TaskGraph


@dataclasses.dataclass
class RunSpec:
    """One exploration of a campaign grid."""

    name: str
    tdg: TaskGraph
    budget: Budget
    config: ExplorerConfig = dataclasses.field(default_factory=ExplorerConfig)
    initial: Optional[Design] = None


_COMPLEXITY_KEYS = ("components", "noc_components", "variation")


def _complexity_by_policy(
    results: Iterable[ExplorationResult],
) -> Dict[str, List[Dict[str, float]]]:
    """Best-design `Design.complexity_metrics()` grouped by policy name —
    shared by `CampaignResult.policy_complexity` and the grid aggregate."""
    acc: Dict[str, List[Dict[str, float]]] = {}
    for r in results:
        acc.setdefault(r.policy_name, []).append(
            r.best_design.complexity_metrics()
        )
    return acc


@dataclasses.dataclass
class CampaignResult:
    runs: Dict[str, ExplorationResult]  # per-run, keyed by RunSpec.name
    aggregate: Dict[str, float]  # convergence statistics over the grid
    backend_stats: Dict[str, BackendStats]  # per shared backend (workload name)
    wall_s: float

    def converged_runs(self) -> List[str]:
        return [n for n, r in self.runs.items() if r.converged]

    def iterations_to_budget(self, cap: Optional[int] = None) -> Dict[str, float]:
        """Per-run iterations-to-budget (censored at ``cap`` / the run's own
        iteration count when not converged) — the policy-comparison metric."""
        return {n: r.iterations_to_budget(cap) for n, r in self.runs.items()}

    def policy_iterations(self, cap: Optional[int] = None) -> Dict[str, float]:
        """Mean iterations-to-budget per policy, read from each run's
        ``policy_name`` — the summary a policy × scenario sweep reports."""
        acc: Dict[str, List[float]] = {}
        for r in self.runs.values():
            acc.setdefault(r.policy_name, []).append(r.iterations_to_budget(cap))
        return {p: statistics.mean(v) for p, v in acc.items()}

    def policy_complexity(self) -> Dict[str, Dict[str, float]]:
        """Mean development-cost metrics of each policy's best designs
        (``Design.complexity_metrics``: component count, NoC-subsystem
        count, heterogeneity variation) — the §5.3 comparison surface for
        ``dev_cost`` vs ``farsi``."""
        return {
            p: {
                k: statistics.mean(m[k] for m in ms)
                for k in _COMPLEXITY_KEYS
            }
            for p, ms in _complexity_by_policy(self.runs.values()).items()
        }


class Campaign:
    """Declarative multi-exploration runner sharing one backend per workload.

    >>> camp = Campaign(db, backend="jax")
    >>> camp.add("audio.s1", g_audio, budget, ExplorerConfig(seed=1))
    >>> camp.add("audio.s2", g_audio, budget, ExplorerConfig(seed=2))
    >>> result = camp.run()   # both searches share one dispatch stream
    """

    def __init__(
        self,
        db: HardwareDatabase,
        backend: Union[str, Callable[[TaskGraph, HardwareDatabase], SimulatorBackend]] = "python",
        store=None,  # Optional[serve.DesignStore]: content-addressed eval cache
    ) -> None:
        self.db = db
        self._backend_spec = backend
        self.specs: List[RunSpec] = []
        self.store = store
        self._scheduler = None  # serve.ContinuousBatchScheduler, built lazily

    # ---- declaration ---------------------------------------------------
    def add(
        self,
        name: str,
        tdg: TaskGraph,
        budget: Budget,
        config: Optional[ExplorerConfig] = None,
        initial: Optional[Design] = None,
    ) -> "Campaign":
        if any(s.name == name for s in self.specs):
            raise ValueError(f"duplicate run name {name!r}")
        config = config or ExplorerConfig()
        # runs share the campaign backend; a config asking for a *different*
        # one would be silently overridden — refuse instead (the default
        # "python" is treated as unset and follows the campaign)
        campaign_be = self._backend_spec if isinstance(self._backend_spec, str) else None
        if config.backend != "python" and config.backend != campaign_be:
            raise ValueError(
                f"run {name!r} requests backend {config.backend!r} but the "
                f"campaign shares backend {self._backend_spec!r} across runs"
            )
        self.specs.append(RunSpec(name, tdg, budget, config, initial))
        return self

    @classmethod
    def sweep(
        cls,
        db: HardwareDatabase,
        workloads: Dict[str, TaskGraph],
        budgets: Union[Budget, Dict[str, Budget]],
        seeds: Iterable[int] = (0,),
        awareness: Sequence[str] = ("farsi",),
        backend: Union[str, Callable] = "python",
        **config_kw,
    ) -> "Campaign":
        """Multi-seed × multi-workload × awareness-ladder grid. Reusing one
        graph object per workload keys every run of it onto one shared
        backend."""
        camp = cls(db, backend=backend)
        if isinstance(backend, str):
            config_kw.setdefault("backend", backend)
        for wl_name, tdg in workloads.items():
            bud = budgets[wl_name] if isinstance(budgets, dict) else budgets
            for level in awareness:
                for seed in seeds:
                    camp.add(
                        f"{wl_name}.{level}.s{seed}",
                        tdg,
                        bud,
                        ExplorerConfig(awareness=level, seed=seed, **config_kw),
                    )
        return camp

    @classmethod
    def policy_sweep(
        cls,
        db: HardwareDatabase,
        scenarios: Sequence,  # Iterable[workloads.Scenario]
        policies: Sequence[str] = ("naive_sa", "farsi"),
        seeds: Iterable[int] = (0,),
        backend: Union[str, Callable] = "python",
        **config_kw,
    ) -> "Campaign":
        """Policy × scenario grid over a generated workload family
        (`workloads.synthetic_family`): every scenario carries its own graph
        and calibrated budget, every policy runs under every seed, and all
        runs of one scenario share one backend. Summarize with
        ``CampaignResult.policy_iterations()``."""
        camp = cls(db, backend=backend)
        if isinstance(backend, str):
            config_kw.setdefault("backend", backend)
        for scen in scenarios:
            for pol in policies:
                for seed in seeds:
                    camp.add(
                        f"{scen.name}.{pol}.s{seed}",
                        scen.tdg,
                        scen.budget,
                        ExplorerConfig(policy=pol, seed=seed, **config_kw),
                    )
        return camp

    # ---- execution -----------------------------------------------------
    def _get_scheduler(self):
        # the serve-layer scheduler IS the campaign engine now; imported
        # lazily because repro.serve builds on repro.core (not a cycle at
        # import time this way)
        if self._scheduler is None:
            from ..serve.scheduler import ContinuousBatchScheduler

            self._scheduler = ContinuousBatchScheduler(
                self.db, self._backend_spec, store=self.store
            )
        return self._scheduler

    def backend_for(self, tdg: TaskGraph) -> SimulatorBackend:
        return self._get_scheduler().backend_for(tdg)

    def run(self) -> CampaignResult:
        """Drive the whole grid through the continuous-batching scheduler.

        Every spec is admitted up front, so the serve loop degenerates to
        exactly the historic lockstep sweep: each tick packs all live runs'
        pending batches per shared backend into one dispatch, and per-row
        results are independent of batch composition — run results and
        aggregates are identical to the pre-scheduler implementation.
        """
        t0 = time.perf_counter()
        if not self.specs:
            raise ValueError("empty campaign: nothing to run")
        from ..serve.session import Session, SessionRequest

        sched = self._get_scheduler()
        sessions: List = []
        for spec in self.specs:
            ex = Explorer(
                spec.tdg, self.db, spec.budget, spec.config,
                backend=sched.backend_for(spec.tdg),
            )
            req = SessionRequest(
                spec.name, spec.tdg, spec.budget, spec.config, spec.initial
            )
            session = Session(req, ex)
            sessions.append(session)
            sched.admit(session)
        sched.run_until_idle()
        # drain: un-consumed dispatches must not outlive the run
        sched.flush()

        runs = {s.name: s.result for s in sessions}  # spec order preserved
        labels = self._backend_labels()
        backend_stats = {
            labels[tdg_id]: b.stats() for tdg_id, b in sched.backends().items()
        }
        aggregate = self._aggregate(runs)
        # content-addressed cache accounting (zeros when no store attached):
        # hits+aliases avoided device rows; bypasses took the scalar path
        hits = sum(s.n_cache_hits for s in backend_stats.values())
        misses = sum(s.n_cache_misses for s in backend_stats.values())
        aggregate["cache_hits_total"] = hits
        aggregate["cache_misses_total"] = misses
        aggregate["cache_bypass_total"] = sum(
            s.n_cache_bypass for s in backend_stats.values()
        )
        aggregate["cache_hit_rate"] = hits / (hits + misses) if hits + misses else 0.0
        return CampaignResult(
            runs=runs,
            aggregate=aggregate,
            backend_stats=backend_stats,
            wall_s=time.perf_counter() - t0,
        )

    def _backend_labels(self) -> Dict[int, str]:
        """One stable label per backend: the graph name, suffixed ``#n`` when
        distinct graph objects share a name (they get distinct backends)."""
        labels: Dict[int, str] = {}
        counts: Dict[str, int] = {}
        for spec in self.specs:
            key = id(spec.tdg)
            if key in labels:
                continue
            n = counts.get(spec.tdg.name, 0)
            labels[key] = spec.tdg.name if n == 0 else f"{spec.tdg.name}#{n}"
            counts[spec.tdg.name] = n + 1
        return labels

    @staticmethod
    def _aggregate(runs: Dict[str, ExplorationResult]) -> Dict[str, float]:
        iters = [r.iterations for r in runs.values()]
        dists = [r.best_distance.city_block() for r in runs.values()]
        conv_iters = [r.iterations for r in runs.values() if r.converged]
        # Fig.-10 co-design aggregates: per-run ledgers used to be dropped on
        # aggregation — surface the grid-level switch-rate / convergence-
        # contribution means alongside the convergence statistics
        codesign = aggregate_ledgers([r.ledger for r in runs.values()])
        # §5.3 development-cost aggregates: grid-level means of the best
        # designs' complexity metrics, plus the headline dev_cost-vs-farsi
        # reductions — reported as the bounded fraction
        # (farsi − dev_cost) / farsi (1.0 = eliminated entirely; the
        # paper's ratio form explodes when dev_cost drives a metric to
        # zero) — when both policies ran in this grid
        by_pol = _complexity_by_policy(runs.values())
        comp = [m for ms in by_pol.values() for m in ms]
        complexity = {
            f"complexity_{k}_mean": statistics.mean(m[k] for m in comp)
            for k in _COMPLEXITY_KEYS
        }
        if "farsi" in by_pol and "dev_cost" in by_pol:
            for k in _COMPLEXITY_KEYS:
                f = statistics.mean(m[k] for m in by_pol["farsi"])
                d = statistics.mean(m[k] for m in by_pol["dev_cost"])
                complexity[f"dev_cost_{k}_reduction"] = (
                    (f - d) / f if f > 0 else 0.0
                )
        return {
            **codesign,
            **complexity,
            "n_runs": len(runs),
            "n_converged": sum(r.converged for r in runs.values()),
            "convergence_rate": statistics.mean(
                [1.0 if r.converged else 0.0 for r in runs.values()]
            ),
            "iterations_mean": statistics.mean(iters),
            "iterations_median": statistics.median(iters),
            "converged_iterations_mean": statistics.mean(conv_iters) if conv_iters else float("nan"),
            "best_distance_mean": statistics.mean(dists),
            "best_distance_max": max(dists),
            "n_sims_total": sum(r.n_sims for r in runs.values()),
            "sim_wall_s_total": sum(r.sim_wall_s for r in runs.values()),
        }
