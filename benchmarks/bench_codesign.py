"""Paper Fig. 10: co-design deployment rates per vector (10b) and their
convergence contribution (10c); plus the co-design ON/OFF ablation (§5.3:
'embedding the same co-design capabilities in regular SA does not necessarily
translate to design improvements').

Both the seed average and the ablation grid run as `Campaign`s over a shared
backend instead of sequential per-seed Explorer loops."""
from __future__ import annotations

import statistics
from typing import List

from repro.core import Campaign, ExplorerConfig, HardwareDatabase, ar_complex, calibrated_budget
from repro.core.codesign import VECTORS

from .common import Row

SEEDS = (1, 2, 3)


def run() -> List[Row]:
    db = HardwareDatabase()
    g = ar_complex()
    bud = calibrated_budget(db)
    rows: List[Row] = []

    camp = Campaign(db)
    for seed in SEEDS:
        camp.add(f"fig10.s{seed}", g, bud, ExplorerConfig(max_iterations=500, seed=seed))
    cres = camp.run()
    summaries = [cres.runs[f"fig10.s{seed}"].ledger.summary() for seed in SEEDS]
    for v in VECTORS:
        sw = statistics.mean(s[v]["switch_rate"] for s in summaries)
        cc = statistics.mean(s[v]["convergence_contribution"] for s in summaries)
        rows.append((f"fig10.{v}", 0.0, f"switch_rate={sw:.2f} convergence_contrib={cc*100:.1f}%"))

    # ON/OFF ablation at fixed iteration budget — one campaign per variant so
    # each label keeps its own aggregate
    for label, codesign, awareness in (
        ("farsi_codesign_on", True, "farsi"),
        ("farsi_codesign_off", False, "farsi"),
        ("sa_codesign_on", True, "sa"),
    ):
        camp = Campaign(db)
        for seed in SEEDS:
            camp.add(
                f"{label}.s{seed}", g, bud,
                ExplorerConfig(awareness=awareness, codesign=codesign, max_iterations=400, seed=seed),
            )
        ares = camp.run()
        iters = [
            r.iterations if r.converged else 400 for r in ares.runs.values()
        ]
        rows.append(
            (
                f"fig10c.{label}",
                0.0,
                f"iters_avg={statistics.mean(iters):.0f} "
                f"dist_avg={ares.aggregate['best_distance_mean']:.3f}",
            )
        )
    return rows
