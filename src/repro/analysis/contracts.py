"""Cross-file layout contracts: invariants that live in two (or more)
files at once and desync silently.

Each :class:`Contract` names the files that must move together, binds the
*real* objects from both sides (import or AST — never a copy of the
expected value), and diffs them. A finding always names every file
involved, because the fix is "edit these together", not "this line is
wrong".

The check logic itself is in pure functions (``check_*``) that take plain
values, so the tests can feed them deliberately-desynced inputs without
monkeypatching modules; the contract wrappers only *bind* real values and
translate messages into :class:`~repro.analysis.findings.Finding` records.

Registered contracts:

``scal-cols``      ``core.scal_layout`` is the single source of truth for
                   the packed scalar-column layout; the Pallas kernel's
                   rollup stack, ``ops.py``'s re-export and the backend's
                   fixed-column math must all agree with it (PR-4/PR-6
                   desync class: a column added on one side only shifts
                   every downstream telemetry read by one).
``chain-carry``    :class:`~repro.core.device_explore.ChainCarry` leaf
                   count/order vs the :class:`MoveTable` row count and the
                   per-class capacity widths ``fresh_carry`` materializes
                   — the PR-9 bug class (taboo column narrower than the
                   move table → silent modulo-aliasing of taboo TTLs).
``move-codes``     the ``MV_*`` code enumeration vs ``_KIND_PRECEDENCE``
                   and the fused block's ``valid =`` dispatch expression —
                   a new move kind must appear in all three.
``policy-registry`` ``POLICIES`` vs per-class ``device_menu`` eligibility
                   vs both tables in ``docs/HEURISTICS.md``.
"""
from __future__ import annotations

import ast
import dataclasses
import os
import re
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from .findings import Finding

__all__ = [
    "Contract",
    "CONTRACTS",
    "run_contracts",
    "check_scal_cols",
    "check_rollup_anchors",
    "check_chain_carry",
    "check_move_codes",
    "check_policy_registry",
    "parse_md_tables",
]

_REPO_SRC = os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))))
_REPO = os.path.dirname(_REPO_SRC)

F_LAYOUT = "src/repro/core/scal_layout.py"
F_KERNEL = "src/repro/kernels/phase_sim/kernel.py"
F_OPS = "src/repro/kernels/phase_sim/ops.py"
F_BACKEND = "src/repro/core/backend.py"
F_DEVEXP = "src/repro/core/device_explore.py"
F_POLICY = "src/repro/core/policy.py"
F_HEUR = "docs/HEURISTICS.md"


@dataclasses.dataclass(frozen=True)
class Contract:
    """One cross-file invariant. ``check`` returns findings (empty = holds)."""

    name: str
    description: str
    files: Tuple[str, ...]
    check: Callable[[], List[Finding]]

    def run(self) -> List[Finding]:
        try:
            return self.check()
        except Exception as e:  # a contract that cannot even bind is a finding
            return [Finding(
                pass_name="contracts", rule=self.name,
                message=f"contract could not bind its subjects: {type(e).__name__}: {e}",
                path=self.files[0], related=self.files[1:],
            )]


def _src(rel: str) -> str:
    with open(os.path.join(_REPO, rel), "r", encoding="utf-8") as fh:
        return fh.read()


# ---------------------------------------------------------------------------
# pure checks (unit-testable with injected, deliberately-desynced values)
# ---------------------------------------------------------------------------
def check_scal_cols(
    layout_cols: Sequence[str],
    kernel_cols: Sequence[str],
    backend_prefix: Sequence[str],
    backend_n_fixed: int,
    rollup_width: Optional[int] = None,
) -> List[str]:
    out: List[str] = []
    if tuple(kernel_cols) != tuple(layout_cols):
        out.append(
            "kernel.SCAL_COLS != scal_layout.SCAL_COLS: "
            f"{tuple(kernel_cols)!r} vs {tuple(layout_cols)!r}"
        )
    if tuple(layout_cols[: len(backend_prefix)]) != tuple(backend_prefix):
        out.append(
            "backend._SCAL_COLS is not a prefix of the layout: "
            f"{tuple(backend_prefix)!r} vs {tuple(layout_cols)!r}"
        )
    if backend_n_fixed != len(layout_cols):
        out.append(
            f"backend._N_FIXED_SCAL ({backend_n_fixed}) != "
            f"len(SCAL_COLS) ({len(layout_cols)}) — every telemetry "
            "column read after the fixed block shifts"
        )
    if rollup_width is not None and rollup_width != len(layout_cols):
        out.append(
            f"the kernel rollup stacks {rollup_width} scalars but "
            f"SCAL_COLS names {len(layout_cols)} — the packed scal row "
            "and its schema disagree"
        )
    return out


# schema-name → source stem that must appear in the kernel rollup element
# at the SAME index. The rollup is positional (a stack of local values, no
# names), so name-diffing alone cannot catch a reordered schema — these
# anchors tie the column name to the expression that computes it.
# latency_s is deliberately unanchored (the kernel calls it `now`).
ROLLUP_ANCHORS = {
    "energy_j": "energy",
    "power_w": "power",
    "area_mm2": "area",
    "fitness": "fitness",
    "alp_time_s": "alp",
    "traffic_bytes": "traffic",
    "n_phases": "nph",
    "all_done": "completed",
    "kind_pe_s": "kind_s[0]",
    "kind_mem_s": "kind_s[1]",
    "kind_noc_s": "kind_s[2]",
    "top_bneck_pe": "pe_b",
    "top_bneck_mem": "mem_b",
}


def check_rollup_anchors(
    layout_cols: Sequence[str], rollup_srcs: Optional[Sequence[str]]
) -> List[str]:
    """The kernel rollup element at each column's index must mention that
    column's anchor stem — catches a reorder of the (single-sourced)
    schema that the tautological name-diff cannot see."""
    if rollup_srcs is None or len(rollup_srcs) != len(layout_cols):
        return []  # width mismatch is already its own finding
    out: List[str] = []
    for i, col in enumerate(layout_cols):
        stem = ROLLUP_ANCHORS.get(col)
        if stem is not None and stem not in rollup_srcs[i]:
            out.append(
                f"SCAL_COLS[{i}] = {col!r} but the kernel rollup element "
                f"there is `{rollup_srcs[i]}` (expected it to mention "
                f"{stem!r}) — the schema and the kernel's positional "
                "stack have desynced"
            )
    return out


# the PR-8 mapping-only carry prefix: checkpoints and parity tests iterate
# these leaves positionally, so their order is load-bearing
CARRY_PREFIX = (
    "task_pe", "task_mem", "fitness", "key", "taboo", "pe_bneck", "mem_bneck",
)


def check_chain_carry(
    field_names: Sequence[str],
    taboo_width: int,
    n_moves: int,
    pe_widths: Dict[str, int],
    cap_pe: int,
    mem_widths: Dict[str, int],
    cap_mem: int,
    state_fields: Optional[Sequence[str]] = None,
) -> List[str]:
    out: List[str] = []
    if tuple(field_names[: len(CARRY_PREFIX)]) != CARRY_PREFIX:
        out.append(
            "ChainCarry's first leaves are no longer the PR-8 prefix "
            f"{CARRY_PREFIX!r} (got {tuple(field_names[:7])!r}) — "
            "checkpoints and parity tests iterate leaves positionally"
        )
    if taboo_width != n_moves:
        out.append(
            f"fresh_carry taboo width ({taboo_width}) != MoveTable.n_moves "
            f"({n_moves}) — taboo TTLs silently alias across move rows "
            "(the PR-9 desync)"
        )
    for col, w in pe_widths.items():
        if w != cap_pe:
            out.append(
                f"carry.{col} width ({w}) != cap_pe ({cap_pe}) — the "
                "fused block scatters by slot index into this column"
            )
    for col, w in mem_widths.items():
        if w != cap_mem:
            out.append(
                f"carry.{col} width ({w}) != cap_mem ({cap_mem})"
            )
    if state_fields is not None:
        state = tuple(state_fields)
        expect = tuple(
            f for f in field_names
            if f not in ("fitness", "key", "taboo", "pe_bneck", "mem_bneck")
        )
        if state != expect:
            missing = [f for f in expect if f not in state]
            extra = [f for f in state if f not in expect]
            out.append(
                "_build_block._STATE does not cover the carry's swap-on-"
                f"accept leaves (missing {missing!r}, extra {extra!r}) — "
                "an uncovered leaf keeps its rejected value after an accept"
            )
    return out


def check_move_codes(
    codes: Dict[str, int],
    precedence_len: int,
    dispatch_names: Sequence[str],
) -> List[str]:
    out: List[str] = []
    vals = sorted(codes.values())
    if vals != list(range(len(codes))):
        out.append(
            f"MV_* codes are not a dense 0..{len(codes) - 1} enumeration: "
            f"{dict(sorted(codes.items(), key=lambda kv: kv[1]))!r} — the "
            "kind column indexes _KIND_PRECEDENCE positionally"
        )
    for name, v in codes.items():
        want_suffix = "_PE" if v % 2 == 0 else "_MEM"
        if not name.endswith(want_suffix):
            out.append(
                f"{name}={v} breaks the even=PE / odd=MEM convention the "
                "validity mask and apply_move scatter classes rely on"
            )
    if precedence_len != len(codes):
        out.append(
            f"_KIND_PRECEDENCE has {precedence_len} entries for "
            f"{len(codes)} MV_* codes — the farsi menu would read a "
            "precedence off the end (or miss a kind)"
        )
    missing = sorted(set(codes) - set(dispatch_names))
    if missing:
        out.append(
            f"the fused block's `valid =` dispatch never tests {missing!r}"
            " — rows of that kind are unconditionally invalid (dead moves)"
        )
    return out


def check_policy_registry(
    policy_menus: Dict[str, str],
    menus: Sequence[str],
    doc_menu_rows: Dict[str, str],
    doc_listed_policies: Sequence[str],
) -> List[str]:
    out: List[str] = []
    for name, menu in sorted(policy_menus.items()):
        if menu not in menus:
            out.append(
                f"POLICIES[{name!r}].device_menu = {menu!r} is not in "
                f"device_explore.MENUS {tuple(menus)!r}"
            )
        doc = doc_menu_rows.get(name)
        if doc is None:
            out.append(
                f"policy {name!r} is missing from the device-eligibility "
                "table in docs/HEURISTICS.md"
            )
        elif doc != menu:
            out.append(
                f"docs/HEURISTICS.md says {name!r} uses menu {doc!r} but "
                f"the class declares device_menu={menu!r}"
            )
    listed = set(doc_listed_policies)
    for name in sorted(policy_menus):
        if name not in listed:
            out.append(
                f"policy {name!r} is registered but absent from the "
                "'Registered policies' table in docs/HEURISTICS.md"
            )
    for name in sorted(listed - set(policy_menus)):
        out.append(
            f"docs/HEURISTICS.md lists policy {name!r} which is not in "
            "POLICIES"
        )
    for name in sorted(set(doc_menu_rows) - set(policy_menus)):
        out.append(
            f"device-eligibility table names unknown policy {name!r}"
        )
    return out


# ---------------------------------------------------------------------------
# markdown table parsing (docs/HEURISTICS.md is a contract subject)
# ---------------------------------------------------------------------------
def parse_md_tables(text: str) -> List[List[List[str]]]:
    """All pipe-tables in a markdown document as lists of rows of cell
    strings (header row included, separator rows dropped)."""
    tables: List[List[List[str]]] = []
    cur: List[List[str]] = []
    for line in text.splitlines():
        s = line.strip()
        if s.startswith("|") and s.endswith("|"):
            cells = [c.strip() for c in s[1:-1].split("|")]
            if all(re.fullmatch(r":?-{3,}:?", c) for c in cells):
                continue
            cur.append(cells)
        else:
            if cur:
                tables.append(cur)
                cur = []
    if cur:
        tables.append(cur)
    return tables


def _ticked(cell: str) -> List[str]:
    return re.findall(r"`([^`]+)`", cell)


def _heuristics_doc_bindings(text: str) -> Tuple[Dict[str, str], List[str]]:
    """(policy → documented menu) from the device-eligibility table, and
    the policy names listed in the registered-policies table."""
    menu_rows: Dict[str, str] = {}
    listed: List[str] = []
    for table in parse_md_tables(text):
        header = [c.lower() for c in table[0]]
        if header[:2] == ["name", "selection"]:
            for row in table[1:]:
                listed.extend(_ticked(row[0]))
        elif header[0] == "policy" and "device_menu" in header[1]:
            for row in table[1:]:
                menus = _ticked(row[1])
                menu = menus[0] if menus else ""
                for name in _ticked(row[0]):
                    menu_rows[name] = menu
    return menu_rows, listed


# ---------------------------------------------------------------------------
# AST extraction helpers (the side of a contract that is *code shape*)
# ---------------------------------------------------------------------------
def _find_func(tree: ast.Module, name: str) -> Optional[ast.FunctionDef]:
    for node in ast.walk(tree):
        if isinstance(node, ast.FunctionDef) and node.name == name:
            return node
    return None


def kernel_rollup_sources(src: str) -> Optional[List[str]]:
    """Source text of each element of the ``scal_ref[0] = jnp.stack([...])``
    rollup in the Pallas kernel — the packed scal row, positionally."""
    tree = ast.parse(src)
    for node in ast.walk(tree):
        if not (isinstance(node, ast.Assign) and len(node.targets) == 1):
            continue
        t = node.targets[0]
        if not (
            isinstance(t, ast.Subscript)
            and isinstance(t.value, ast.Name)
            and t.value.id == "scal_ref"
        ):
            continue
        v = node.value
        if (
            isinstance(v, ast.Call)
            and isinstance(v.func, ast.Attribute)
            and v.func.attr == "stack"
            and v.args
            and isinstance(v.args[0], (ast.List, ast.Tuple))
        ):
            return [ast.unparse(e) for e in v.args[0].elts]
    return None


def kernel_rollup_width(src: str) -> Optional[int]:
    srcs = kernel_rollup_sources(src)
    return None if srcs is None else len(srcs)


def dispatch_mv_names(src: str) -> List[str]:
    """Every ``MV_*`` name referenced in the ``valid = …`` expression of
    ``_build_block``'s step function."""
    tree = ast.parse(src)
    fn = _find_func(tree, "_build_block")
    if fn is None:
        return []
    names: List[str] = []
    for node in ast.walk(fn):
        if isinstance(node, ast.Assign) and any(
            isinstance(t, ast.Name) and t.id == "valid" for t in node.targets
        ):
            for sub in ast.walk(node.value):
                if isinstance(sub, ast.Name) and sub.id.startswith("MV_"):
                    names.append(sub.id)
    return sorted(set(names))


def state_tuple_fields(src: str) -> Optional[List[str]]:
    """The ``_STATE`` tuple literal inside ``_build_block`` — the carry
    leaves the accept step swaps wholesale."""
    tree = ast.parse(src)
    fn = _find_func(tree, "_build_block")
    if fn is None:
        return None
    for node in ast.walk(fn):
        if isinstance(node, ast.Assign) and any(
            isinstance(t, ast.Name) and t.id == "_STATE" for t in node.targets
        ):
            if isinstance(node.value, (ast.Tuple, ast.List)):
                return [
                    e.value for e in node.value.elts
                    if isinstance(e, ast.Constant) and isinstance(e.value, str)
                ]
    return None


# ---------------------------------------------------------------------------
# contract bindings (real imports / real fixtures)
# ---------------------------------------------------------------------------
def _msgs_to_findings(
    msgs: List[str], rule: str, path: str, related: Tuple[str, ...]
) -> List[Finding]:
    return [
        Finding(pass_name="contracts", rule=rule, message=m,
                path=path, related=related)
        for m in msgs
    ]


def _check_scal() -> List[Finding]:
    from repro.core import backend, scal_layout
    from repro.kernels.phase_sim import kernel, ops

    rollup = kernel_rollup_sources(_src(F_KERNEL))
    msgs = check_scal_cols(
        layout_cols=scal_layout.SCAL_COLS,
        kernel_cols=kernel.SCAL_COLS,
        backend_prefix=backend._SCAL_COLS,
        backend_n_fixed=backend._N_FIXED_SCAL,
        rollup_width=None if rollup is None else len(rollup),
    )
    if rollup is None:
        msgs.append(
            "could not locate the `scal_ref[0] = jnp.stack([...])` rollup "
            "in the kernel — the scal-cols contract lost its anchor"
        )
    msgs.extend(check_rollup_anchors(scal_layout.SCAL_COLS, rollup))
    if tuple(ops.SCAL_COLS) != tuple(scal_layout.SCAL_COLS):
        msgs.append("ops.SCAL_COLS re-export drifted from the layout")
    # the index constants must keep addressing what their names claim
    if scal_layout.SCAL_COLS[scal_layout.KIND_START:scal_layout.KIND_STOP] \
            != scal_layout.BNECK_KIND_COLS:
        msgs.append("KIND_START/KIND_STOP no longer bracket the "
                    "bneck-kind triple")
    if (scal_layout.SCAL_COLS[scal_layout.TOP_PE_COL],
            scal_layout.SCAL_COLS[scal_layout.TOP_MEM_COL]) \
            != scal_layout.TOP_BNECK_COLS:
        msgs.append("TOP_PE_COL/TOP_MEM_COL do not address the "
                    "top-bottleneck pair")
    return _msgs_to_findings(
        msgs, "scal-cols", F_LAYOUT, (F_KERNEL, F_OPS, F_BACKEND)
    )


def _carry_fixture():
    """Smallest real binding: the audio workload on a random single-NoC
    design, alloc menu over deliberately non-pow2 capacities (a pow2
    assumption hiding in a width computation must not pass by luck)."""
    from repro.core import (
        DeviceChainRunner, HardwareDatabase, audio, random_single_noc_designs,
    )
    from repro.core.phase_sim_jax import EncodedDesign

    db = HardwareDatabase()
    g = audio()
    d = random_single_noc_designs(g, 1, seed=7)[0]
    runner = DeviceChainRunner(g, db)
    ed = EncodedDesign.of(d, g, db, runner.enc)
    cap_pe = int(ed.pe_peak.shape[0]) + 3
    cap_mem = int(ed.mem_bw.shape[0]) + 2
    return runner, d, ed, cap_pe, cap_mem


def _check_carry() -> List[Finding]:
    from repro.core.device_explore import ChainCarry, MoveTable

    runner, d, ed, cap_pe, cap_mem = _carry_fixture()
    table = MoveTable.of(
        ed, runner.enc, alloc=True, cap_pe=cap_pe, cap_mem=cap_mem
    )
    carry = runner.fresh_carry(
        d, ed, r=2, seed=0, cap_pe=cap_pe, cap_mem=cap_mem, alloc=True
    )
    pe_cols = ("pe_bneck", "pe_active", "pe_peak", "pe_pj", "pe_leak",
               "pe_area", "pe_noc", "pe_rung", "pe_src")
    mem_cols = ("mem_bneck", "mem_active", "mem_bw", "mem_pj", "mem_leak",
                "mem_area_fixed", "mem_area_per_mb", "mem_noc", "mem_rung",
                "mem_src")
    msgs = check_chain_carry(
        field_names=ChainCarry._fields,
        taboo_width=int(carry.taboo.shape[1]),
        n_moves=table.n_moves,
        pe_widths={c: int(getattr(carry, c).shape[1]) for c in pe_cols},
        cap_pe=cap_pe,
        mem_widths={c: int(getattr(carry, c).shape[1]) for c in mem_cols},
        cap_mem=cap_mem,
        state_fields=state_tuple_fields(_src(F_DEVEXP)),
    )
    if len(carry) != len(ChainCarry._fields):
        msgs.append(
            f"fresh_carry returned {len(carry)} leaves for a "
            f"{len(ChainCarry._fields)}-field ChainCarry"
        )
    t = len(runner.enc.names)
    if tuple(carry.accel.shape) != (2, t, cap_pe):
        msgs.append(
            f"carry.accel shape {tuple(carry.accel.shape)} != (R, T, "
            f"cap_pe) = (2, {t}, {cap_pe})"
        )
    return _msgs_to_findings(msgs, "chain-carry", F_DEVEXP, ())


def _check_moves() -> List[Finding]:
    from repro.core import device_explore as dx

    codes = {
        n: int(getattr(dx, n))
        for n in dir(dx)
        if n.startswith("MV_") and isinstance(getattr(dx, n), int)
    }
    msgs = check_move_codes(
        codes=codes,
        precedence_len=int(dx._KIND_PRECEDENCE.shape[0]),
        dispatch_names=dispatch_mv_names(_src(F_DEVEXP)),
    )
    return _msgs_to_findings(msgs, "move-codes", F_DEVEXP, ())


def _check_policies() -> List[Finding]:
    from repro.core.device_explore import MENUS
    from repro.core.policy import POLICIES

    doc_menus, doc_listed = _heuristics_doc_bindings(_src(F_HEUR))
    msgs = check_policy_registry(
        policy_menus={n: cls.device_menu for n, cls in POLICIES.items()},
        menus=MENUS,
        doc_menu_rows=doc_menus,
        doc_listed_policies=doc_listed,
    )
    return _msgs_to_findings(msgs, "policy-registry", F_POLICY, (F_HEUR, F_DEVEXP))


CONTRACTS: Tuple[Contract, ...] = (
    Contract(
        name="scal-cols",
        description="packed scal-column layout: kernel rollup ↔ ops "
        "re-export ↔ backend fixed-column math ↔ core.scal_layout",
        files=(F_LAYOUT, F_KERNEL, F_OPS, F_BACKEND),
        check=_check_scal,
    ),
    Contract(
        name="chain-carry",
        description="ChainCarry leaves ↔ MoveTable row count ↔ fresh_carry "
        "widths ↔ _build_block._STATE coverage (PR-9 taboo-width class)",
        files=(F_DEVEXP,),
        check=_check_carry,
    ),
    Contract(
        name="move-codes",
        description="MV_* enumeration ↔ _KIND_PRECEDENCE ↔ fused-block "
        "validity dispatch",
        files=(F_DEVEXP,),
        check=_check_moves,
    ),
    Contract(
        name="policy-registry",
        description="POLICIES ↔ device_menu eligibility ↔ both "
        "docs/HEURISTICS.md tables",
        files=(F_POLICY, F_HEUR, F_DEVEXP),
        check=_check_policies,
    ),
)


def run_contracts(
    names: Optional[Sequence[str]] = None,
) -> List[Finding]:
    """Run the registry (or the named subset) and return all findings."""
    out: List[Finding] = []
    for c in CONTRACTS:
        if names is not None and c.name not in names:
            continue
        out.extend(c.run())
    return out
