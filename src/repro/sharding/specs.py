"""Logical sharding axes for every parameter / cache / input tensor.

Every tensor dim gets a *logical* name; ``rules.py`` maps logical names to
mesh axes and resolves conflicts/divisibility per-array. This is the
MaxText-style logical-axis-rules pattern — and the substrate FARSI's
``migrate`` move mutates when auto-tuning the distribution (launch/autotune).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

from ..configs.base import ModelConfig, ShapeConfig

L = Tuple[Optional[str], ...]  # logical axes of one array


def _attn_logical(cfg: ModelConfig) -> Dict[str, L]:
    p: Dict[str, L] = {
        "wq": ("embed", "qkv"),
        "wk": ("embed", "kv_qkv"),
        "wv": ("embed", "kv_qkv"),
        "wo": ("qkv", "embed"),
    }
    if cfg.qk_norm:
        p["q_norm"] = (None,)
        p["k_norm"] = (None,)
    return p


def _mamba_logical(cfg: ModelConfig) -> Dict[str, L]:
    return {
        "in_proj": ("embed", "ssm_inner"),
        "conv_w": (None, "ssm_conv"),
        "conv_b": ("ssm_conv",),
        "a_log": (None,),
        "d_skip": (None,),
        "dt_bias": (None,),
        "norm": ("ssm_inner",),
        "out_proj": ("ssm_inner", "embed"),
    }


def _mlp_logical(cfg: ModelConfig) -> Dict[str, L]:
    p: Dict[str, L] = {"wi_gate": ("embed", "mlp"), "wo": ("mlp", "embed")}
    if cfg.mlp_kind != "gelu":
        p["wi_up"] = ("embed", "mlp")
    return p


def _moe_logical(cfg: ModelConfig) -> Dict[str, L]:
    return {
        "router": ("embed", None),
        "wi_gate": ("experts", "embed", "expert_mlp"),
        "wi_up": ("experts", "embed", "expert_mlp"),
        "wo": ("experts", "expert_mlp", "embed"),
    }


def param_logical(cfg: ModelConfig) -> Dict[str, Any]:
    """Mirror of ``models.model.init_params`` with logical names per dim.
    Stacked per-cycle leaves get a leading 'layers' axis."""
    layers = []
    for pos in range(cfg.cycle_len):
        kind = cfg.block_kinds[pos]
        p: Dict[str, Any] = {"norm1": (None,)}
        p["mixer"] = _attn_logical(cfg) if kind == "attn" else _mamba_logical(cfg)
        mk = cfg.mlp_kind_at(pos)
        if mk == "dense":
            p["norm2"] = (None,)
            p["mlp"] = _mlp_logical(cfg)
        elif mk == "moe":
            p["norm2"] = (None,)
            p["mlp"] = _moe_logical(cfg)
        # prepend the stacking axis
        import jax

        p = jax.tree.map(lambda ax: ("layers",) + ax, p, is_leaf=lambda x: isinstance(x, tuple))
        layers.append(p)
    out: Dict[str, Any] = {"layers": layers, "final_norm": (None,)}
    if cfg.input_mode == "tokens":
        # the token-gather dim must never shard (SPMD turns a gather over a
        # sharded dim into a full all-gather of the table); D shards FSDP-style
        out["embed"] = ("vocab_table", "embed")
    if not (cfg.tie_embeddings and cfg.input_mode == "tokens"):
        out["lm_head"] = ("embed", "vocab")
    return out


def cache_logical(cfg: ModelConfig, kv_quant: str = "none") -> tuple:
    caches = []
    for kind in cfg.block_kinds:
        if kind == "attn":
            # kv_heads shards over 'model' when divisible; otherwise the
            # resolver falls through to head_dim (split-contraction decode)
            spec = ("layers", "batch", "cache_seq", "kv_heads", "head_dim")
            if kv_quant == "int8":
                sspec = ("layers", "batch", "cache_seq", "kv_heads")
                caches.append({"k": spec, "v": spec, "k_scale": sspec, "v_scale": sspec})
                continue
            caches.append({"k": spec, "v": spec})
        else:
            caches.append(
                {
                    "conv": ("layers", "batch", None, "ssm_conv"),
                    "ssm": ("layers", "batch", "ssm_heads", None, None),
                }
            )
    return tuple(caches)


def batch_logical(cfg: ModelConfig, kind: str) -> Dict[str, L]:
    """Input batch tensors for train/prefill ('seq' length S) or decode (S=1)."""
    out: Dict[str, L] = {}
    if cfg.input_mode == "tokens":
        out["tokens"] = ("batch", "seq")
    else:
        out["embeds"] = ("batch", "seq", "act_embed")
    if kind == "train":
        out["labels"] = ("batch", "seq")
    if cfg.rope_kind == "mrope":
        out["mrope_positions"] = (None, "batch", "seq")
    return out
