"""Mixture-of-Experts layer: top-k routing with capacity-based dispatch.

Dispatch uses the deterministic position-in-expert construction (one-hot
cumsum over the token axis — GShard/Switch style) so every shape is static
under jit/pjit: tokens beyond an expert's capacity are dropped (standard
capacity-factor semantics), and the combine weights renormalize the kept
experts per token.

Sharding: tokens (B·S) ride the data axes; expert weights (E, D, F) shard E
over the model axis when E divides it (EP) and F otherwise (expert-TP) — see
``repro.sharding.rules``. XLA's SPMD partitioner materializes the token
exchange as all-to-all / all-gather collectives; the §Perf loop tunes which.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..sharding.act import constrain


def moe_init(key: jax.Array, cfg: ModelConfig, dtype) -> dict:
    d, f, e = cfg.d_model, cfg.moe_d_ff or cfg.d_ff, cfg.n_experts
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s_in, s_out = 1.0 / math.sqrt(d), 1.0 / math.sqrt(f)
    return {
        "router": (jax.random.normal(k1, (d, e)) * s_in).astype(jnp.float32),
        "wi_gate": (jax.random.normal(k2, (e, d, f)) * s_in).astype(dtype),
        "wi_up": (jax.random.normal(k3, (e, d, f)) * s_in).astype(dtype),
        "wo": (jax.random.normal(k4, (e, f, d)) * s_out).astype(dtype),
    }


def capacity(n_tokens: int, cfg: ModelConfig) -> int:
    c = int(math.ceil(n_tokens * cfg.top_k * cfg.capacity_factor / cfg.n_experts))
    return max(cfg.top_k, min(c, n_tokens))


def moe_apply(
    params: dict, x: jax.Array, cfg: ModelConfig
) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, D) → (y (B, S, D), aux_loss scalar). Static capacity."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    t = b * s
    c = capacity(t, cfg)
    xf = x.reshape(t, d)

    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32), params["router"])
    probs = jax.nn.softmax(logits, axis=-1)  # (T, E)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)  # (T, k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # load-balancing auxiliary loss (Switch):  E · Σ_e f_e · p_e
    me = probs.mean(axis=0)  # (E,)
    ce = jnp.zeros((e,), jnp.float32).at[expert_idx.reshape(-1)].add(1.0) / (t * k)
    aux = e * jnp.sum(me * ce)

    # position-in-expert via one-hot cumsum over the flat assignment axis
    flat_e = expert_idx.reshape(t * k)  # token-major → earlier tokens win capacity
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)  # (T·k, E)
    pos = (jnp.cumsum(onehot, axis=0) - 1) * onehot
    pos_in_e = pos.sum(axis=-1)  # (T·k,)
    keep = pos_in_e < c

    # scatter into (E·C, D): dropped assignments contribute masked zeros at
    # slot 0 instead of an overflow row, so every flat dim stays divisible
    # and the dispatch tensors can live sharded (they are T·k × d_model —
    # replicating them costs tens of GB/device at 1M-token prefill)
    slot = jnp.where(keep, flat_e * c + pos_in_e, 0)
    x_rep = jnp.broadcast_to(xf[:, None, :], (t, k, d)).reshape(t * k, d)
    x_rep = constrain(x_rep, ("moe_flat", None))
    x_rep = x_rep * keep[:, None].astype(x.dtype)
    buf = jnp.zeros((e * c, d), x.dtype).at[slot].add(x_rep)
    grouped = buf.reshape(e, c, d)
    # pin the dispatch buffer to the expert-parallel layout: E over 'model',
    # capacity over the data axes (the token exchange lowers to all-to-all)
    grouped = constrain(grouped, ("experts", "exp_capacity", None))

    # expert MLPs (grouped einsum — the Megablocks-style GMM fusion target)
    gate = jnp.einsum("ecd,edf->ecf", grouped, params["wi_gate"])
    up = jnp.einsum("ecd,edf->ecf", grouped, params["wi_up"])
    if cfg.mlp_kind == "geglu":
        act = jax.nn.gelu(gate.astype(jnp.float32), approximate=True).astype(x.dtype)
    else:
        act = jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype)
    h = jnp.einsum("ecf,efd->ecd", act * up, params["wo"])  # (E, C, D)
    h = constrain(h, ("experts", "exp_capacity", None))

    # combine: gather each kept assignment back, weight by its gate
    # (dropped assignments gather slot 0 and are zeroed by the keep mask)
    h_flat = h.reshape(e * c, d)
    y_rep = h_flat[slot] * (gate_vals.reshape(t * k, 1) * keep[:, None]).astype(h.dtype)
    y_rep = constrain(y_rep, ("moe_flat", None))
    y = y_rep.reshape(t, k, d).sum(axis=1)
    return y.reshape(b, s, d), aux
