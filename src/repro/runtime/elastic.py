"""Elastic scaling: re-map a training state onto a different mesh.

Checkpoints are topology-free (plain numpy per leaf), so elasticity reduces
to re-deriving shardings for the *current* mesh from the same logical rules
and re-placing leaves. ``shrink_mesh`` proposes the largest viable mesh from
the surviving device count (keeping the model axis intact first — TP degree
is baked into layout efficiency; the data axis absorbs losses, with the
global batch re-split across fewer data shards).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import Mesh

from ..configs.base import ModelConfig, ShapeConfig
from ..sharding.rules import default_rules, tree_shardings
from ..sharding.specs import param_logical


def shrink_mesh(n_devices: int, model_axis: int = 16) -> Tuple[Tuple[int, ...], Tuple[str, ...]]:
    """Largest (data, model) mesh with data a power of two that fits
    ``n_devices``. Falls back to smaller model axes if necessary."""
    while model_axis > 1:
        if n_devices >= model_axis:
            data = 1
            while data * 2 * model_axis <= n_devices:
                data *= 2
            return (data, model_axis), ("data", "model")
        model_axis //= 2
    return (max(n_devices, 1), 1), ("data", "model")


def state_shardings(
    cfg: ModelConfig,
    shape: ShapeConfig,
    mesh: Mesh,
    state_struct: Any,
    rules: Optional[Dict] = None,
):
    """Shardings for a {params, opt{m,v,count}, step} train state on ``mesh``."""
    rules = rules or default_rules(cfg, shape, mesh)
    p_logical = param_logical(cfg)
    logical = {
        "params": p_logical,
        "opt": {"m": p_logical, "v": p_logical, "count": ()},
        "step": (),
    }
    return tree_shardings(state_struct, logical, rules, mesh)


def reshard_state(state: Any, shardings: Any) -> Any:
    """Re-place every leaf with the new sharding (cross-mesh device_put)."""
    return jax.tree.map(jax.device_put, state, shardings)
