"""Integration: the multi-pod dry-run path end-to-end in a subprocess (the
XLA_FLAGS=512-devices header must run before jax init, so it gets its own
process). Uses the two cheapest cells to keep CI time bounded; the full
64-cell sweep lives in experiments/dryrun/."""
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.parametrize(
    "arch,shape,multi",
    [
        ("mamba2-370m", "decode_32k", False),
        ("qwen2-vl-2b", "decode_32k", True),
    ],
)
def test_dryrun_cell_subprocess(arch, shape, multi):
    cmd = [
        sys.executable, "-m", "repro.launch.dryrun",
        "--arch", arch, "--shape", shape,
    ] + (["--multi-pod"] if multi else [])
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    env.pop("XLA_FLAGS", None)  # dryrun must set it itself
    out = subprocess.run(
        cmd, cwd=REPO, env=env, capture_output=True, text=True, timeout=480
    )
    assert out.returncode == 0, out.stdout + out.stderr
    assert "1/1 cells OK" in out.stdout


def test_launch_train_cli_subprocess():
    """The production launcher end-to-end on the host mesh."""
    cmd = [
        sys.executable, "-m", "repro.launch.train",
        "--arch", "qwen3-1.7b", "--reduced", "--steps", "6",
        "--seq-len", "32", "--global-batch", "4", "--save-every", "3",
        "--ckpt-dir", "/tmp/repro_cli_test_ckpt",
    ]
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run(
        cmd, cwd=REPO, env=env, capture_output=True, text=True, timeout=480
    )
    assert out.returncode == 0, out.stdout + out.stderr
    assert "done: 6 steps" in out.stdout
