"""Chaos sweep for the serve fault-tolerance layer: seeded fault injection
across {dispatch-error, NaN-row, straggler, coroutine-crash}, asserting zero
service crashes, deterministic replay, unaffected-session bit-identity, the
retry/degradation/restart ladders, deadline SLOs, and counter reconciliation
between the injector's schedule and ServiceStats.

Everything here is tier-1 (small B, ~12-iteration searches): the isolation
guarantees are exactly the kind of property that silently rots without a
fast gate.
"""
import math

import pytest

from repro.core import (
    ExplorerConfig,
    HardwareDatabase,
    calibrated_budget,
    edge_detection,
)
from repro.serve import (
    DeadlineExceeded,
    DseService,
    FaultInjector,
    InjectedSessionCrash,
    RetryPolicy,
    SessionFailed,
)

N = 4  # sessions per chaos run
ITERS = 12

# no real sleeping inside tier-1 retries
FAST_RETRY = RetryPolicy(backoff_s=0.0)


@pytest.fixture(scope="module")
def db():
    return HardwareDatabase()


@pytest.fixture(scope="module")
def g(db):
    return edge_detection()


@pytest.fixture(scope="module")
def bud(db):
    return calibrated_budget(db)


def _cfg(i, backend="jax"):
    return ExplorerConfig(seed=i, backend=backend, max_iterations=ITERS)


def _run(db, g, bud, faults=None, n=N, backend="jax", retry=FAST_RETRY, **submit_kw):
    svc = DseService(db, backend=backend, faults=faults, retry=retry)
    handles = [
        svc.submit(f"s{i}", g, bud, _cfg(i, backend), **submit_kw)
        for i in range(n)
    ]
    stats = svc.run()  # the headline guarantee: this must never raise
    return svc, handles, stats


def _distances(svc):
    return {n: r.best_distance.city_block() for n, r in svc.results().items()}


@pytest.fixture(scope="module")
def baseline(db, g, bud):
    """Fault-free reference results for the bit-identity assertions."""
    svc, handles, stats = _run(db, g, bud, faults=None, n=6)
    assert stats.n_done == 6 and stats.n_failed == 0
    assert stats.n_dispatch_faults == 0 and stats.n_nonfinite_rejected == 0
    return _distances(svc)


# ---- the sweep: every fault kind, zero service crashes --------------------
@pytest.mark.parametrize(
    "kind,rates",
    [
        ("dispatch", dict(dispatch_fault_rate=0.3)),
        ("nan_row", dict(nan_row_rate=0.15)),
        ("straggler", dict(straggler_rate=0.3, straggler_delay_s=0.001)),
        ("crash", dict(crash_rate=0.05)),
        ("combined", dict(dispatch_fault_rate=0.1, nan_row_rate=0.05,
                          straggler_rate=0.05, straggler_delay_s=0.001,
                          crash_rate=0.02)),
    ],
)
def test_chaos_sweep_no_service_crash(db, g, bud, kind, rates):
    """With faults injected at seeded rates, no exception escapes
    DseService.run(), every session reaches a terminal state, and the
    ServiceStats counters reconcile with the injector's schedule."""
    fi = FaultInjector(seed=7, **rates)
    svc, handles, stats = _run(db, g, bud, faults=fi, max_restarts=2)
    counts = fi.counts()

    assert svc.n_live == 0  # nothing stuck
    assert stats.n_done + stats.n_failed == N
    for h in handles:
        assert h.done or h.failed
        if h.failed:
            assert h.error is not None
            with pytest.raises(SessionFailed):
                h.result

    # counter reconciliation against the injection schedule: injected
    # dispatch vetoes are the only dispatch-failure source in this sweep
    assert stats.n_dispatch_faults == counts["dispatch"]
    assert stats.n_nonfinite_rejected <= counts["nan_row"]
    assert stats.n_restarts + sum(
        1 for h in handles
        if h.failed and isinstance(h.error, InjectedSessionCrash)
    ) <= counts["crash"]
    # every completed search ended on a finite committed design
    for h in handles:
        if h.done:
            assert math.isfinite(h.result.best_distance.city_block())


def test_deterministic_replay(db, g, bud):
    """Same injector seed → same fault schedule → same per-session results:
    every injection decision is drawn at scheduler-deterministic points,
    never gated on wall clock."""
    rates = dict(dispatch_fault_rate=0.1, nan_row_rate=0.05,
                 straggler_rate=0.05, straggler_delay_s=0.001, crash_rate=0.02)

    def go():
        fi = FaultInjector(seed=7, **rates)
        svc, handles, stats = _run(db, g, bud, faults=fi, max_restarts=2)
        seqs = {
            name: [(h["move"], h["accepted"]) for h in r.history]
            for name, r in svc.results().items()
        }
        return fi.schedule, _distances(svc), seqs, stats

    sched_a, dist_a, seq_a, st_a = go()
    sched_b, dist_b, seq_b, st_b = go()
    assert sched_a == sched_b  # identical injection schedule (tick/kind/target)
    assert dist_a == dist_b  # bit-identical outcomes
    assert seq_a == seq_b  # identical accepted-move sequences
    assert (st_a.n_dispatch_faults, st_a.n_restarts, st_a.n_failed) == (
        st_b.n_dispatch_faults, st_b.n_restarts, st_b.n_failed
    )


def test_unaffected_sessions_bit_identical(db, g, bud, baseline):
    """Session-level isolation: sessions the injector never poisoned or
    crashed (and that never degraded or failed) walk the exact accepted-move
    sequence of a fault-free run — co-batched faults cost their owner, not
    the batch."""
    fi = FaultInjector(seed=1, nan_row_rate=0.03, crash_rate=0.01)
    svc, handles, stats = _run(db, g, bud, faults=fi, n=6, max_restarts=1)
    affected = fi.affected_sessions() | set(svc.failures())
    affected |= {name for name, s in svc._sessions.items() if s.degraded}
    unaffected = [name for name in baseline if name not in affected]
    # the seed is pinned so the assertion actually covers something
    assert len(unaffected) >= 2
    got = _distances(svc)
    for name in unaffected:
        assert got[name] == baseline[name]  # bit-identical, not approx


# ---- retry / degradation ladder -------------------------------------------
def test_transient_dispatch_faults_are_invisible(db, g, bud, baseline):
    """A transient dispatch fault is retried (after bisecting the shared
    batch); because the injector vetoes BEFORE submission and per-row
    results are independent of batch composition, the retried rows — and
    therefore every session's result — are bit-identical to fault-free."""
    fi = FaultInjector(seed=0, dispatch_fault_rate=1.0, max_faults=3)
    svc, handles, stats = _run(db, g, bud, faults=fi)
    assert stats.n_done == N and stats.n_failed == 0
    assert stats.n_dispatch_faults == 3 == fi.counts()["dispatch"]
    assert stats.n_bisects == 1  # the poisoned shared dispatch was split
    assert stats.n_retries >= 1  # and at least one member backed off
    assert stats.n_degraded == 0
    got = _distances(svc)
    for name, d in got.items():
        assert d == baseline[name]


def test_degradation_ladder(db, g, bud):
    """After degrade_after consecutive failed primary dispatches a session
    falls back — per-session — to the PythonBackend: with a 100% injected
    dispatch-fault rate every session degrades, yet all complete and the
    service never stops serving."""
    fi = FaultInjector(seed=0, dispatch_fault_rate=1.0)
    svc, handles, stats = _run(db, g, bud, faults=fi)
    assert stats.n_done == N and stats.n_failed == 0
    assert stats.n_degraded == N
    assert all(h.degraded and h.done for h in handles)
    # the injected-fault tally: 1 failed shared dispatch + degrade_after
    # per-session attempts each, all before the fallback takes over (which
    # the injector never vetoes — degraded pricing is the recovery path)
    assert stats.n_dispatch_faults == 1 + N * FAST_RETRY.degrade_after
    assert stats.n_dispatch_faults == fi.counts()["dispatch"]
    assert stats.n_degraded_evals > 0  # fallback did the pricing...
    bstats = svc.backend_stats()
    assert bstats["ed~degraded"].n_sims == stats.n_degraded_evals
    assert bstats["ed"].n_sims == 0  # ...and the device priced nothing


def test_chain_session_degrades_to_host_loop_bit_identically(db, g, bud):
    """Chain-batched sessions ride the same ladder: with a 100% injected
    dispatch-fault rate the fused-block session degrades to the host-loop
    regime (K dispatches of the same compiled step at k=1) instead of the
    scalar fallback — and by the R=1-parity contract the degraded search
    walks the exact move/accept/fitness history of the fault-free run:
    degradation changes dispatch granularity, never the search."""
    def chain_cfg():
        return ExplorerConfig(policy="device_sa", seed=3, max_iterations=16,
                              chain_r=4, chain_k=8, chain_alloc=True,
                              backend="jax")

    ref_svc = DseService(db, backend="jax", retry=FAST_RETRY)
    ref = ref_svc.submit("chain", g, bud, chain_cfg())
    ref_stats = ref_svc.run()
    assert ref_stats.n_done == 1 and ref_stats.n_degraded == 0
    assert ref.result.chained

    fi = FaultInjector(seed=0, dispatch_fault_rate=1.0)
    svc = DseService(db, backend="jax", faults=fi, retry=FAST_RETRY)
    h = svc.submit("chain", g, bud, chain_cfg())
    stats = svc.run()
    assert stats.n_done == 1 and stats.n_failed == 0
    assert stats.n_degraded == 1 and h.degraded and h.done
    # every primary fused-block attempt was vetoed; the host loop (never
    # vetoed — it IS the recovery path) priced everything after that
    assert stats.n_dispatch_faults == FAST_RETRY.degrade_after
    res = h.result
    assert res.chained and res.chain_r == 4
    hist = [(e["iteration"], e["move"], e["accepted"], e["fitness"])
            for e in res.history]
    ref_hist = [(e["iteration"], e["move"], e["accepted"], e["fitness"])
                for e in ref.result.history]
    assert hist == ref_hist


# ---- crash restart ---------------------------------------------------------
def test_crash_restart_resumes_from_committed_state(db, g, bud, baseline):
    """A crashed coroutine with restart budget is rebuilt from the
    explorer's last committed accept (rng + policy.checkpoint()/restore());
    the replayed rng stream makes the restarted search bit-identical to the
    uncrashed one."""
    fi = FaultInjector(seed=0, crash_rate=1.0, max_faults=1)
    svc, handles, stats = _run(db, g, bud, faults=fi, n=2, max_restarts=1)
    assert stats.n_done == 2 and stats.n_failed == 0
    assert stats.n_restarts == 1 == fi.counts()["crash"]
    assert _distances(svc)["s0"] == baseline["s0"]


def test_crash_without_restart_budget_fails_session(db, g, bud):
    fi = FaultInjector(seed=0, crash_rate=1.0, max_faults=1)
    svc, handles, stats = _run(db, g, bud, faults=fi, n=2)  # max_restarts=0
    assert stats.n_failed == 1 and stats.n_restarts == 0
    assert handles[0].failed
    assert isinstance(handles[0].error, InjectedSessionCrash)
    assert handles[1].done  # the co-batched session is untouched


# ---- deadlines -------------------------------------------------------------
def test_deadline_exceeded_surfaces_on_handle(db, g, bud):
    svc = DseService(db, backend="jax")
    doomed = svc.submit("doomed", g, bud, _cfg(0), deadline_s=0.0)
    ok = svc.submit("ok", g, bud, _cfg(1))
    stats = svc.run()
    assert stats.n_deadline_exceeded == 1 and stats.n_failed == 1
    assert doomed.failed and isinstance(doomed.error, DeadlineExceeded)
    with pytest.raises(SessionFailed) as ei:
        doomed.result
    assert isinstance(ei.value.__cause__, DeadlineExceeded)
    assert ok.done and stats.n_done == 1


# ---- non-finite guard ------------------------------------------------------
def test_nan_rows_rejected_never_accepted(db, g, bud):
    """Poisoned fitness/scalar rows are clamped out of the ranking and can
    never be accepted: every session completes on a finite best design and
    the rejection counter reconciles with the injection schedule."""
    fi = FaultInjector(seed=3, nan_row_rate=0.3)
    svc, handles, stats = _run(db, g, bud, faults=fi)
    assert stats.n_done == N and stats.n_failed == 0
    injected = fi.counts()["nan_row"]
    assert injected > 0
    assert 0 < stats.n_nonfinite_rejected <= injected
    for h in handles:
        assert math.isfinite(h.result.best_distance.city_block())
        for e in h.events:  # streamed improvements are committed accepts
            assert math.isfinite(e.distance) and math.isfinite(e.fitness)


# ---- stragglers ------------------------------------------------------------
def test_straggler_ticks_flagged_by_monitor(db, g, bud):
    """Injected dispatch latency makes the tick a genuine outlier; the
    wired-in StepTimeMonitor EMA flags it (warmup ticks excluded) and the
    count surfaces in ServiceStats."""
    fi = FaultInjector(seed=1, straggler_rate=0.25, straggler_delay_s=0.4)
    svc, handles, stats = _run(db, g, bud, faults=fi, n=3, backend="python")
    assert stats.n_done == 3 and stats.n_failed == 0
    straggler_ticks = {f.tick for f in fi.schedule if f.kind == "straggler"}
    assert straggler_ticks  # the pinned seed schedules stragglers...
    flagged = {s.step for s in svc.scheduler.monitor.flagged}
    assert flagged & straggler_ticks  # ...and the monitor caught them
    assert stats.n_straggler_ticks == len(flagged) >= 1
