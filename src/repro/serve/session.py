"""One multi-tenant exploration session around the Explorer coroutine.

A :class:`Session` owns one :meth:`~repro.core.explorer.Explorer.run_steps`
generator and the bookkeeping the scheduler needs to co-batch it with
strangers: the pending candidate batch, lifecycle state, streamed
best-design events, and per-session latency/throughput accounting. The
session never talks to a backend — the scheduler prices its pending batch
(packed with every other live session's) and hands the matching
``SimHandle`` slice back through :meth:`resume`.

Streaming contract: every committed best-so-far improvement fires a
:class:`BestEvent` (wired to ``Explorer.on_improve`` — scalar columns only,
no decode); the final decoded winner arrives once, in the
``ExplorationResult`` captured at ``StopIteration``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional, Sequence

from ..core.backend import Candidate, SimHandle
from ..core.budgets import Budget
from ..core.design import Design
from ..core.explorer import ExplorationResult, Explorer, ExplorerConfig
from ..core.tdg import TaskGraph

PENDING = "pending"
RUNNING = "running"
DONE = "done"


@dataclasses.dataclass
class SessionRequest:
    """One exploration request, shaped like ``campaign.RunSpec`` — the serve
    layer's admission unit."""

    name: str
    tdg: TaskGraph
    budget: Budget
    config: ExplorerConfig = dataclasses.field(default_factory=ExplorerConfig)
    initial: Optional[Design] = None


@dataclasses.dataclass(frozen=True)
class BestEvent:
    """One streamed best-design-so-far improvement (scalars only — the full
    decode is paid once, for the final winner)."""

    session: str
    iteration: int
    distance: float
    fitness: float
    move: str
    converged: bool
    latency_s: float
    power_w: float
    area_mm2: float
    wall_s: float  # seconds since the session was admitted


class Session:
    """Lifecycle: ``PENDING`` (declared) → ``RUNNING`` (``start`` primed the
    coroutine; ``pending`` holds the batch awaiting pricing) → ``DONE``
    (``result`` captured). Joining mid-flight is just calling ``start``
    between two scheduler ticks — co-batching never perturbs a session's
    own search (per-row results are independent of batch composition, which
    is what makes a late joiner converge exactly as if it ran alone)."""

    def __init__(self, request: SessionRequest, explorer: Explorer) -> None:
        self.request = request
        self.explorer = explorer
        self.state = PENDING
        self.pending: List[Candidate] = []
        self.result: Optional[ExplorationResult] = None
        self.events: List[BestEvent] = []
        self.on_event: Optional[Callable[[BestEvent], None]] = None
        self.sim_wall_s = 0.0  # attributed share of shared-dispatch wall
        self.n_ticks = 0
        self.admitted_at: Optional[float] = None
        self.done_at: Optional[float] = None
        explorer.on_improve = self._improved

    @property
    def name(self) -> str:
        return self.request.name

    @property
    def done(self) -> bool:
        return self.state == DONE

    @property
    def latency_s(self) -> float:
        """Admission → completion wall clock (the serve latency metric);
        admission → now while still running."""
        if self.admitted_at is None:
            return 0.0
        end = self.done_at if self.done_at is not None else time.perf_counter()
        return end - self.admitted_at

    def _improved(self, ev: dict) -> None:
        event = BestEvent(
            session=self.request.name,
            iteration=ev["iteration"],
            distance=ev["distance"],
            fitness=ev["fitness"],
            move=ev["move"],
            converged=ev["converged"],
            latency_s=ev["latency_s"],
            power_w=ev["power_w"],
            area_mm2=ev["area_mm2"],
            wall_s=time.perf_counter() - (self.admitted_at or time.perf_counter()),
        )
        self.events.append(event)
        if self.on_event is not None:
            self.on_event(event)

    # ---- scheduler interface --------------------------------------------
    def start(self) -> None:
        """Prime the coroutine: after this the session is RUNNING and
        ``pending`` holds its first candidate batch (the initial design)."""
        assert self.state == PENDING, f"session {self.name!r} already started"
        self.admitted_at = time.perf_counter()
        self._gen = self.explorer.run_steps(self.request.initial)
        try:
            self.pending = next(self._gen)
            self.state = RUNNING
        except StopIteration as stop:  # pragma: no cover — degenerate search
            self._finish(stop.value)

    def resume(self, handles: Sequence[SimHandle]) -> bool:
        """Feed the priced handles for the current ``pending`` batch; returns
        True when the session just completed."""
        assert self.state == RUNNING, self.state
        self.n_ticks += 1
        try:
            self.pending = self._gen.send(list(handles))
            return False
        except StopIteration as stop:
            self._finish(stop.value)
            return True

    def _finish(self, result: ExplorationResult) -> None:
        result.sim_wall_s = self.sim_wall_s
        self.result = result
        self.pending = []
        self.state = DONE
        self.done_at = time.perf_counter()
