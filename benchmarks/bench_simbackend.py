"""SimulatorBackend shoot-out: scalar-Python vs vmap-batched-JAX evaluation.

Measures the two things the API redesign is for, and writes them to
``BENCH_simbackend.json`` (next to this file) so future PRs can track the
speedup trajectory:

  1. neighbour-evaluation throughput — the same candidate batch priced by
     ``PythonBackend`` (simulate() per design) and by a warm
     ``JaxBatchedBackend`` (one `vmap` dispatch), in designs/second;
  2. end-to-end explorer iteration rate — a fixed-seed exploration run with
     each backend, in iterations/second (jit warm-up excluded via a short
     priming run so the number reflects steady-state search).
"""
from __future__ import annotations

import json
import os
from typing import List

from repro.core import (
    Explorer,
    ExplorerConfig,
    HardwareDatabase,
    JaxBatchedBackend,
    PythonBackend,
    ar_complex,
    audio,
    calibrated_budget,
    random_single_noc_designs,
)

from .common import Row, timeit

JSON_PATH = os.path.join(os.path.dirname(os.path.abspath(__file__)), "BENCH_simbackend.json")
BATCH = 64  # campaign-scale cross-batch (explorer alone submits 4/iteration)
EXPLORE_ITERS = 120


def run() -> List[Row]:
    db = HardwareDatabase()
    payload = {"batch": BATCH, "explore_iterations": EXPLORE_ITERS, "workloads": {}}
    rows: List[Row] = []

    # audio (15 tasks) and the full AR complex (28 tasks) — the two paper
    # workload scales where batching is the DSE's operating point
    for g in (audio(), ar_complex()):
        designs = random_single_noc_designs(g, BATCH, seed=7)
        py = PythonBackend(g, db)
        jx = JaxBatchedBackend(g, db)
        jx.evaluate(designs)  # compile once; steady state is what the DSE sees
        py.evaluate(designs)
        # interleave the samples so both backends see the same machine
        # conditions (scheduler noise on small graphs otherwise skews ratios)
        t_py = t_jx = float("inf")
        for _ in range(7):
            t_py = min(t_py, timeit(lambda: py.evaluate(designs), n=1))
            t_jx = min(t_jx, timeit(lambda: jx.evaluate(designs), n=1))
        evals_py = BATCH / (t_py * 1e-6)
        evals_jx = BATCH / (t_jx * 1e-6)

        # end-to-end: fixed-seed exploration per backend (prime the jit cache
        # with a short run so shape-bucket compiles don't bill the measure run)
        bud = calibrated_budget(db)
        Explorer(g, db, bud, ExplorerConfig(max_iterations=EXPLORE_ITERS, seed=2),
                 backend=jx).run()
        iters = {}
        for name, backend in (("python", py), ("jax", jx)):
            ex = Explorer(
                g, db, bud,
                ExplorerConfig(max_iterations=EXPLORE_ITERS, seed=3),
                backend=backend,
            )
            res = ex.run()
            iters[name] = {
                "iterations": res.iterations,
                "wall_s": res.wall_s,
                "sim_wall_s": res.sim_wall_s,
                "iters_per_s": res.iterations / max(res.wall_s, 1e-9),
                "converged": res.converged,
            }

        payload["workloads"][g.name] = {
            "n_tasks": len(g.tasks),
            "python_evals_per_s": evals_py,
            "jax_evals_per_s": evals_jx,
            "eval_throughput_speedup": evals_jx / max(evals_py, 1e-9),
            "explorer": iters,
            "explorer_iters_per_s_speedup": (
                iters["jax"]["iters_per_s"] / max(iters["python"]["iters_per_s"], 1e-9)
            ),
        }
        rows.append(
            (
                f"simbackend.{g.name}.eval_throughput",
                t_jx / BATCH,
                f"jax={evals_jx:.0f}/s python={evals_py:.0f}/s "
                f"speedup={evals_jx/max(evals_py,1e-9):.1f}x batch={BATCH}",
            )
        )
        rows.append(
            (
                f"simbackend.{g.name}.explorer",
                iters["jax"]["wall_s"] * 1e6,
                f"jax={iters['jax']['iters_per_s']:.1f}it/s "
                f"python={iters['python']['iters_per_s']:.1f}it/s "
                f"speedup={payload['workloads'][g.name]['explorer_iters_per_s_speedup']:.1f}x",
            )
        )

    with open(JSON_PATH, "w") as f:
        json.dump(payload, f, indent=2)
    rows.append(("simbackend.json", 0.0, f"wrote {JSON_PATH}"))
    return rows
