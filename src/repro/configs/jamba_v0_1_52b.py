"""Jamba v0.1 52B [arXiv:2403.19887; hf:ai21labs/Jamba-v0.1].

Hybrid Mamba+attention at 1:7 interleave (one attention layer per 8-layer
cycle, at position 3 as in the release), MoE (16 experts, top-2) on every
other layer. 32L, d_model=4096, 32 q heads / 8 kv heads, d_ff=14336,
vocab=65536. The release uses Mamba-1 blocks; we instantiate Mamba-2 (SSD)
blocks — the state-space-duality form maps onto the MXU as chunked matmuls,
whereas Mamba-1's diagonal scan does not (hardware adaptation, DESIGN.md).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    n_layers=32,
    d_model=4096,
    vocab_size=65536,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    mlp_kind="swiglu",
    n_experts=16,
    top_k=2,
    moe_d_ff=14336,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    rope_kind="none",  # Jamba uses no positional encoding in attention
    block_kinds=("mamba", "mamba", "mamba", "attn", "mamba", "mamba", "mamba", "mamba"),
    mlp_kinds=("dense", "moe", "dense", "moe", "dense", "moe", "dense", "moe"),
    subquadratic=True,  # 4 attention layers; KV cache is small => long_500k runs
)
