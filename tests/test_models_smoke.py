"""Per-arch smoke tests (deliverable f): every assigned architecture at a
reduced config runs one forward and one train step on CPU — output shapes
right, no NaNs, loss finite and decreasing-capable."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.registry import ARCHS, arch_names, reduced_config
from repro.models.model import RunFlags, forward, init_cache, init_params, decode_step
from repro.optim.adamw import AdamWConfig
from repro.train.step import init_train_state, make_train_step

B, S = 2, 32


def _batch(cfg, key):
    if cfg.input_mode == "tokens":
        b = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    else:
        b = {"embeds": jax.random.normal(key, (B, S, cfg.d_model), jnp.bfloat16)}
    b["labels"] = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    return b


@pytest.mark.parametrize("name", arch_names())
def test_forward_shapes_and_finite(name, rng_key):
    cfg = reduced_config(name)
    params = init_params(cfg, rng_key)
    batch = _batch(cfg, rng_key)
    logits, aux, _ = forward(params, cfg, {k: v for k, v in batch.items() if k != "labels"})
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("name", arch_names())
def test_one_train_step(name, rng_key):
    cfg = reduced_config(name)
    state = init_train_state(cfg, rng_key)
    step = make_train_step(cfg, RunFlags(attn_impl="full"), AdamWConfig(warmup_steps=1))
    batch = _batch(cfg, rng_key)
    new_state, metrics = jax.jit(step)(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    assert int(new_state["step"]) == 1
    # params actually moved
    d0 = jax.tree.leaves(state["params"])[1]
    d1 = jax.tree.leaves(new_state["params"])[1]
    assert not bool(jnp.allclose(d0, d1))


@pytest.mark.parametrize("name", ["jamba-v0.1-52b", "qwen3-moe-235b-a22b", "mamba2-370m", "gemma-7b"])
def test_decode_step_finite(name, rng_key):
    cfg = reduced_config(name)
    params = init_params(cfg, rng_key, dtype=jnp.bfloat16)
    cache = init_cache(cfg, B, S)
    if cfg.input_mode == "tokens":
        batch = {"tokens": jnp.zeros((B, 1), jnp.int32)}
    else:
        batch = {"embeds": jnp.zeros((B, 1, cfg.d_model), jnp.bfloat16)}
    logits, new_cache = decode_step(params, cfg, cache, batch, jnp.int32(3))
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())


def test_full_configs_param_counts():
    """Full configs match their advertised sizes (±10%)."""
    expected = {
        "jamba-v0.1-52b": 52e9,
        "qwen3-1.7b": 1.7e9,
        "mistral-large-123b": 123e9,
        "starcoder2-7b": 7e9,
        "gemma-7b": 8.5e9,
        "qwen3-moe-235b-a22b": 235e9,
        "grok-1-314b": 314e9,
        "qwen2-vl-2b": 1.5e9,
        "musicgen-large": 2.4e9,
        "mamba2-370m": 0.37e9,
    }
    for name, target in expected.items():
        got = ARCHS[name].param_counts()["total"]
        assert abs(got - target) / target < 0.10, (name, got, target)
    # MoE actives
    assert abs(ARCHS["qwen3-moe-235b-a22b"].param_counts()["active"] - 22e9) / 22e9 < 0.1
