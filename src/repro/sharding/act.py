"""Logical activation-sharding constraints.

``constrain(x, logical_axes)`` pins an intermediate tensor's sharding via
``lax.with_sharding_constraint`` using the active (rules, mesh) context; a
no-op when no context is installed (single-device tests/examples).

Why this exists: SPMD propagation alone picks bad shardings at contraction
conflicts — e.g. the tied-embedding LM head (contracting dim FSDP-sharded on
the weight, batch dim data-sharded on the activation) makes XLA replicate the
*batch* of the fp32 logits (observed: 39.8 GB/device). Constraining
activations at block boundaries keeps batch on the data axes everywhere.
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Optional, Tuple

import jax
from jax.sharding import NamedSharding

from .rules import resolve

_CTX: contextvars.ContextVar = contextvars.ContextVar("act_rules", default=None)


@contextlib.contextmanager
def activation_rules(rules: dict, mesh):
    """Install (rules, mesh) for the duration of a trace (jit/lower call)."""
    token = _CTX.set((rules, mesh))
    try:
        yield
    finally:
        _CTX.reset(token)


def constrain(x: jax.Array, logical: Tuple[Optional[str], ...]) -> jax.Array:
    ctx = _CTX.get()
    if ctx is None:
        return x
    rules, mesh = ctx
    spec = resolve(x.shape, logical, rules, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def current_context():
    """(rules, mesh) if a distribution context is installed, else None —
    lets layers pick shard_map implementations only when actually sharded."""
    return _CTX.get()
