"""Optional-hypothesis shim: property tests skip cleanly when the library is
absent, while the plain pytest tests in the same modules keep running.

Usage (instead of ``from hypothesis import given, settings, strategies``):

    from _optional_hypothesis import given, settings, st

When hypothesis is installed these are the real objects. When it is not,
``st`` swallows any strategy-building expression and ``given`` replaces the
test with a skip marker — so module import (and collection) always succeeds.
"""
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAS_HYPOTHESIS = True
except ModuleNotFoundError:
    HAS_HYPOTHESIS = False

    class _AnyStrategy:
        """Absorbs arbitrary strategy expressions: st.lists(...).filter(...)."""

        def __call__(self, *args, **kwargs):
            return self

        def __getattr__(self, name):
            return self

    st = _AnyStrategy()

    def given(*_args, **_kwargs):
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn

        return deco
