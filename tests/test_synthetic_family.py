"""Generative workload-scenario family + the policy × scenario Campaign
sweep (the credibility axis: breadth of scenarios, per DS3/SoC-Tuner)."""
import math

import pytest
from _optional_hypothesis import given, settings, st

from repro.core import (
    Campaign,
    HardwareDatabase,
    simulate,
    synthetic_family,
)
from repro.core.design import Design
from repro.core.workloads import synthetic_budget

DB = HardwareDatabase()


def test_family_is_deterministic_and_sized():
    a = synthetic_family(seed=3, n=4, db=DB)
    b = synthetic_family(seed=3, n=4, db=DB)
    assert [s.name for s in a] == [s.name for s in b]
    for x, y in zip(a, b):
        assert list(x.tdg.tasks) == list(y.tdg.tasks)
        assert x.tdg.edge_bytes == y.tdg.edge_bytes
        assert x.budget == y.budget
    # distinct seeds generate distinct graphs
    c = synthetic_family(seed=4, n=4, db=DB)
    assert any(
        list(x.tdg.tasks) != list(z.tdg.tasks) or x.tdg.edge_bytes != z.tdg.edge_bytes
        for x, z in zip(a, c)
    )


@given(st.integers(0, 10**6), st.integers(1, 8))
@settings(max_examples=15, deadline=None)
def test_family_graphs_acyclic_with_consistent_budgets(seed, n):
    """Property: every generated scenario validates as a DAG, stays within
    the requested size envelope, and carries a budget consistent with its
    own graph — latency key matches the graph name, the target sits between
    the analytic ideal floor and the base design's simulated latency, and
    power/area are positive and finite."""
    for scen in synthetic_family(seed=seed, n=n, db=DB, min_tasks=5, max_tasks=12):
        g = scen.tdg
        g.validate()  # raises on cycles / dangling edges
        assert 5 <= len(g.tasks) <= 12 + 1  # +1: the closing sink
        assert len(g.roots()) == 1
        sinks = [t for t in g.tasks if not g.children[t]]
        assert len(sinks) == 1
        bud = scen.budget
        assert set(bud.latency_s) == {g.name}
        base_lat = simulate(Design.base(g), g, DB).latency_s
        assert 0.0 < bud.latency_s[g.name] < base_lat
        assert math.isfinite(bud.power_w) and bud.power_w > 0
        assert math.isfinite(bud.area_mm2) and bud.area_mm2 > 0


def test_synthetic_budget_speedup_target():
    scen = synthetic_family(seed=1, n=1, db=DB)[0]
    base_lat = simulate(Design.base(scen.tdg), scen.tdg, DB).latency_s
    tight = synthetic_budget(scen.tdg, DB, speedup_target=4.0)
    assert tight.latency_s[scen.tdg.name] == pytest.approx(base_lat / 4.0)


def test_policy_scenario_sweep_through_campaign():
    """Acceptance bar: a policy × scenario grid (≥ 6 synthetic scenarios)
    runs through one Campaign, and FarsiPolicy reaches budget in no more
    iterations than NaiveSA on ≥ 4 of them (strictly fewer on ≥ 4, in
    fact, under these seeds)."""
    cap = 150
    scens = synthetic_family(seed=0, n=6, db=DB)
    camp = Campaign.policy_sweep(
        DB, scens, policies=("naive_sa", "farsi"), seeds=(0,),
        backend="python", max_iterations=cap,
    )
    res = camp.run()
    assert len(res.runs) == 12
    wins = 0
    for s in scens:
        farsi = res.runs[f"{s.name}.farsi.s0"]
        naive = res.runs[f"{s.name}.naive_sa.s0"]
        assert farsi.policy_name == "farsi" and naive.policy_name == "naive_sa"
        if farsi.iterations_to_budget(cap) < naive.iterations_to_budget(cap):
            wins += 1
    assert wins >= 4, res.iterations_to_budget(cap)
    # per-policy aggregate ranks the same way
    means = res.policy_iterations(cap)
    assert means["farsi"] < means["naive_sa"]
    # satellite: Fig.-10 co-design aggregates survive campaign aggregation
    for v in ("metric", "workload", "comm_comp", "opt_level"):
        assert f"codesign_switch_rate_{v}" in res.aggregate
        assert f"codesign_contribution_{v}" in res.aggregate
    assert 0.0 <= res.aggregate["codesign_switch_rate_metric"] <= 1.0
