"""Co-design ledger (paper §5.3, Fig. 10).

FARSI "uses co-design by not being fixated on one optimization for too long"
— every iteration re-selects its focus along four vectors:

  1. metric          (performance / power / area)
  2. workload        (audio / cava / ed / ...)
  3. comp ↔ comm     (is the targeted bottleneck a PE or a Mem/NoC?)
  4. optimization    high-level (mapping/allocation) ↔ low-level (knob tuning),
                     and the concrete move kind

The ledger records the focus tuple per iteration; *deployment rate* of a
vector = how often consecutive iterations switched focus on it (Fig. 10b);
*convergence contribution* = mean distance improvement in iterations that
switched vs. did not (Fig. 10c).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

from .moves import HIGH_LEVEL

VECTORS = ("metric", "workload", "comm_comp", "opt_level")


@dataclasses.dataclass
class FocusRecord:
    iteration: int
    metric: str
    workload: str
    comm_comp: str  # "comp" | "comm"
    move: str
    distance_before: float
    distance_after: float

    @property
    def opt_level(self) -> str:
        return "high" if self.move in HIGH_LEVEL else "low"

    def vector_value(self, vector: str) -> str:
        return {
            "metric": self.metric,
            "workload": self.workload,
            "comm_comp": self.comm_comp,
            "opt_level": self.opt_level,
        }[vector]


class CodesignLedger:
    def __init__(self) -> None:
        self.records: List[FocusRecord] = []

    def log(self, rec: FocusRecord) -> None:
        self.records.append(rec)

    # ---- Fig. 10b: deployment (switch) rate per vector -------------------
    def switch_rate(self, vector: str) -> float:
        if len(self.records) < 2:
            return 0.0
        switches = sum(
            1
            for a, b in zip(self.records, self.records[1:])
            if a.vector_value(vector) != b.vector_value(vector)
        )
        return switches / (len(self.records) - 1)

    # ---- Fig. 10c: convergence rate attribution --------------------------
    def convergence_contribution(self, vector: str) -> float:
        """Mean relative distance improvement in iterations that switched
        focus on ``vector`` (positive = switching helped)."""
        gains = []
        for a, b in zip(self.records, self.records[1:]):
            if a.vector_value(vector) != b.vector_value(vector):
                if b.distance_before > 0:
                    gains.append(
                        (b.distance_before - b.distance_after) / b.distance_before
                    )
        return sum(gains) / len(gains) if gains else 0.0

    def summary(self) -> Dict[str, Dict[str, float]]:
        return {
            v: {
                "switch_rate": self.switch_rate(v),
                "convergence_contribution": self.convergence_contribution(v),
            }
            for v in VECTORS
        }

    def move_histogram(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for r in self.records:
            out[r.move] = out.get(r.move, 0) + 1
        return out


def aggregate_ledgers(ledgers: List["CodesignLedger"]) -> Dict[str, float]:
    """Campaign-level Fig.-10 aggregates: mean switch rate and convergence
    contribution per co-design vector over a grid of runs (runs with too few
    records contribute their zeros, like the per-run summaries do). Keys are
    flat (``codesign_switch_rate_<vector>`` / ``codesign_contribution_
    <vector>``) so they merge into `Campaign`'s scalar aggregate dict."""
    out: Dict[str, float] = {}
    n = max(len(ledgers), 1)
    for v in VECTORS:
        out[f"codesign_switch_rate_{v}"] = (
            sum(l.switch_rate(v) for l in ledgers) / n
        )
        out[f"codesign_contribution_{v}"] = (
            sum(l.convergence_contribution(v) for l in ledgers) / n
        )
    return out
